"""TransferPlan/TransferSession: plan build, per-leaf routing, geometric
capacity schedule, and execution parity across whole-tensor / chunked /
cross-pod targets (the api_redesign acceptance: one plan, three executions,
bit-identical results — including fp32 and fp8 leaves and forced-overflow
retry paths)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import codebook as cbm
from repro.core import codec as C
from repro.core.pipeline import (CodecProfile, pipeline_makespan,
                                 pipelined_transfer_time)
from repro.serving import transfer as T
from repro.serving.plan import (FP8_DEFAULT_CODEBOOK, TransferConfig,
                                TransferPlan)

BF16_CB = cbm.Codebook(fmt="bf16", exponents=tuple(range(118, 134)))


def _mixed_cache(seed=0, seq=128):
    """bf16 KV + fp32 recurrent state + fp8 activations + int passthrough."""
    rng = np.random.default_rng(seed)
    def kv(shape):
        x = rng.normal(size=shape) * rng.choice([0.25, 1.0, 4.0], size=shape)
        return jnp.asarray(x, dtype=jnp.bfloat16)
    return {
        "k": kv((4, 2, seq, 4, 32)),
        "v": kv((4, 2, seq, 4, 32)),
        "ssm": jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.float32),
        "act8": jnp.asarray(rng.normal(size=(4, 256)) * 0.5, jnp.float8_e5m2),
        "pos": jnp.arange(seq, dtype=jnp.int32),
    }


def _cache_cb(cache):
    leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
              for x in jax.tree.leaves(cache) if x.dtype == jnp.bfloat16]
    return cbm.calibrate(leaves, k=16)


def _assert_bit_identical(a_tree, b_tree):
    for a, b in zip(jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)):
        w = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[a.dtype.itemsize]
        if jnp.issubdtype(a.dtype, jnp.integer):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_array_equal(
                np.asarray(jax.lax.bitcast_convert_type(a, w)),
                np.asarray(jax.lax.bitcast_convert_type(b, w)))


class TestPlanBuild:
    def test_routing_table(self):
        cache = _mixed_cache()
        cb = _cache_cb(cache)
        tc = TransferConfig(codebook=cb, compress_fp32=True, n_chunks=4)
        plan = TransferPlan.build(cache, tc)
        routes = plan.route_map()
        assert routes["k"].route == "splitzip"
        assert routes["v"].route == "splitzip"
        assert routes["ssm"].route == "fp32_hilo"
        assert routes["act8"].route == "fp8"
        assert routes["pos"].route == "raw"
        # fp32 hi halves fold into the stream alongside the bf16 bits
        assert plan.stream_len == (cache["k"].size + cache["v"].size
                                   + cache["ssm"].size)
        assert plan.granularity == "chunked"
        desc = plan.describe()
        for word in ("splitzip", "fp32_hilo", "fp8", "raw", "chunked"):
            assert word in desc

    def test_disabled_plan_routes_everything_raw(self):
        cache = _mixed_cache()
        plan = TransferPlan.build(
            cache, TransferConfig(codebook=BF16_CB, enabled=False, n_chunks=8))
        assert all(r.route == "raw" for r in plan.routes)
        assert plan.granularity == "tensor" and plan.stream_len == 0

    def test_segments_are_chunk_aligned_and_cover_stream(self):
        cache = _mixed_cache()
        tc = TransferConfig(codebook=_cache_cb(cache), n_chunks=5, chunk=1024)
        plan = TransferPlan.build(cache, tc)
        assert plan.segments[0].start == 0
        assert plan.segments[-1].stop == plan.stream_len
        for a, b in zip(plan.segments, plan.segments[1:]):
            assert a.stop == b.start
            assert a.n_elements % tc.chunk == 0  # all but last aligned
        assert len(plan.segments) <= 5

    def test_build_from_abstract_structure(self):
        cache = _mixed_cache()
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        tc = TransferConfig(codebook=_cache_cb(cache), n_chunks=4)
        plan_a = TransferPlan.build(abstract, tc)
        plan_c = TransferPlan.build(cache, tc)
        assert plan_a.routes == plan_c.routes
        assert plan_a.segments == plan_c.segments
        assert plan_a.matches(cache)

    def test_matches_rejects_different_structure(self):
        cache = _mixed_cache()
        tc = TransferConfig(codebook=_cache_cb(cache))
        plan = TransferPlan.build(cache, tc)
        other = dict(cache, k=cache["k"][:, :1])
        assert not plan.matches(other)
        sess = plan.session()
        with pytest.raises(ValueError):
            sess.transfer(other)

    def test_mesh_plan_rejects_host_backend(self):
        from repro.launch.mesh import make_mesh
        cache = {"k": jnp.zeros((4, 8), jnp.bfloat16)}
        # single-device 'mesh' is enough to exercise build-time validation
        with pytest.raises((ValueError, AssertionError)):
            TransferPlan.build(cache, TransferConfig(codebook=BF16_CB,
                                                     backend="wire"),
                               mesh=jax.sharding.Mesh(
                                   np.array(jax.devices()[:1]).reshape(1),
                                   ("pod",)))


class TestCapacitySchedule:
    def test_geometric_then_global(self):
        be = B.get_backend("xla")
        steps = be.capacity_schedule("chunked", 64, 1 << 20)
        caps = [c for _, _, c in steps]
        layouts = [l for _, l, _ in steps]
        assert caps[:3] == [64, 128, 256]
        assert layouts[:3] == ["chunked"] * 3
        assert layouts[-1] == "global"
        assert caps[-1] >= 2 * caps[-2]

    def test_zero_doublings_disables_retries(self):
        be = B.get_backend("xla")
        assert be.capacity_schedule("chunked", 64, 1 << 20, doublings=0) == (
            (be, "chunked", 64),)
        rng = np.random.default_rng(13)
        bits = rng.integers(0, 1 << 16, 4096).astype(np.uint16)
        cache = {"a": jax.lax.bitcast_convert_type(jnp.asarray(bits),
                                                   jnp.bfloat16)}
        tc = TransferConfig(codebook=BF16_CB, cap=4, n_chunks=2,
                            retry_doublings=0)
        out, st = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert not st.all_ok and st.n_retry_steps == 0  # fail-fast to raw

    def test_fused_global_retry_switches_structure(self):
        be = B.PallasBackend()
        steps = be.capacity_schedule("global", 128, 1 << 16)
        # retries must route through the two-stage structure (no level-1 cap)
        assert any(isinstance(s[0], B.PallasBackend) and not s[0].fused
                   for s in steps[1:])


class TestExecutionParity:
    """One plan, executed whole-tensor vs chunked: bit-identical, and the
    accounting matches the route table."""

    @pytest.mark.parametrize("backend", ("xla", "pallas"))
    def test_whole_vs_chunked_with_fp32_and_fp8(self, backend):
        cache = _mixed_cache(seed=1)
        cb = _cache_cb(cache)
        mk = lambda n: TransferConfig(codebook=cb, backend=backend,
                                      compress_fp32=True, n_chunks=n)
        out_whole = TransferPlan.build(cache, mk(1)).session().transfer(cache)
        sess = TransferPlan.build(cache, mk(4)).session()
        out_chunk = sess.transfer(cache)
        _assert_bit_identical(cache, out_whole)
        _assert_bit_identical(cache, out_chunk)
        _assert_bit_identical(out_whole, out_chunk)
        st = sess.last_stats
        assert len(st.chunk_wire_bytes) == len(sess.plan.segments)
        assert st.all_ok
        # fp32 leaves are IN the pipe (hi) + counted lo halves, not silent raw
        assert st.fp32_lo_wire_bytes == 2.0 * cache["ssm"].size
        assert st.fp8_wire_bytes > 0
        assert st.raw_passthrough_bytes == cache["pos"].size * 4
        assert st.n_elements == sess.plan.stream_len
        # the folded stream compresses: chunks beat their raw u16 bytes
        assert sum(st.chunk_wire_bytes) < 2.0 * sess.plan.stream_len

    def test_send_recv_equals_fused_transfer(self):
        cache = _mixed_cache(seed=2)
        cb = _cache_cb(cache)
        tc = TransferConfig(codebook=cb, compress_fp32=True, n_chunks=3)
        plan = TransferPlan.build(cache, tc)
        s1, s2 = plan.session(), plan.session()
        out_fused = s1.transfer(cache)
        s2.send(cache)
        out_split = s2.recv()
        _assert_bit_identical(out_fused, out_split)
        assert s1.last_stats.chunk_wire_bytes == s2.last_stats.chunk_wire_bytes
        with pytest.raises(RuntimeError):
            s2.recv()                      # nothing staged
        s2.send(cache)
        with pytest.raises(RuntimeError):
            s2.send(cache)                 # double send

    def test_session_accumulates_across_calls(self):
        cache = _mixed_cache(seed=3)
        tc = TransferConfig(codebook=_cache_cb(cache), n_chunks=2)
        sess = TransferPlan.build(cache, tc).session()
        sess.transfer(cache)
        one = sess.total_wire_bytes
        sess.transfer(cache)
        assert sess.calls == 2
        assert sess.total_wire_bytes == pytest.approx(2 * one)

    def test_shim_matches_session(self):
        cache = _mixed_cache(seed=4)
        tc = TransferConfig(codebook=_cache_cb(cache), n_chunks=4)
        out_shim, st_shim = T.transfer_cache_chunked(cache, tc)
        sess = TransferPlan.build(cache, tc,
                                  granularity="chunked").session()
        out_sess = sess.transfer(cache)
        _assert_bit_identical(out_shim, out_sess)
        assert st_shim.chunk_wire_bytes == sess.last_stats.chunk_wire_bytes

    def test_compress_cache_shim_roundtrips_fp8(self):
        cache = _mixed_cache(seed=5)
        tc = TransferConfig(codebook=_cache_cb(cache), compress_fp32=True)
        comp, raw = T.compress_cache(cache, tc)
        assert "act8" in comp              # fp8 e5m2 repack route
        assert "ssm#hi" in comp and "ssm#lo" in raw
        out = T.decompress_cache(comp, raw, cache)
        _assert_bit_identical(cache, out)


class TestGeometricRetry:
    def _stream_cache(self, bits: np.ndarray):
        return {"a": jax.lax.bitcast_convert_type(jnp.asarray(bits),
                                                  jnp.bfloat16)}

    def test_schedule_recovers_via_global_switch(self):
        """A chunk whose escapes blow cap, 2cap and 4cap but fit the global
        pool must be recovered by the schedule's last step (ok stays True,
        3 extra attempts recorded)."""
        n = 8192
        bits = np.full(n, np.uint16(120 << 7), dtype=np.uint16)
        # 40 escapes inside ONE codec chunk of segment 0: cap=4 -> 8 -> 16
        # all fail; global 5% pool (256 for a 4096 segment) absorbs them
        bits[:40] = np.uint16(7 << 7)
        tc = TransferConfig(codebook=BF16_CB, cap=4, chunk=1024, n_chunks=2)
        out, st = T.transfer_cache_chunked(self._stream_cache(bits), tc)
        _assert_bit_identical(self._stream_cache(bits), out)
        assert st.chunk_ok[0] and st.all_ok
        assert st.chunk_retried[0] is True
        assert st.chunk_retry_steps[0] == 3      # 2cap, 4cap, global
        assert st.chunk_retry_steps[1] == 0
        assert st.n_retries == 1 and st.n_retry_steps == 3

    def test_schedule_exhaustion_falls_back_to_raw(self):
        rng = np.random.default_rng(6)
        bits = rng.integers(0, 1 << 16, 4096).astype(np.uint16)  # all-escape
        tc = TransferConfig(codebook=BF16_CB, cap=4, n_chunks=2)
        cache = self._stream_cache(bits)
        out, st = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert not st.all_ok
        # every failing chunk walked the whole schedule before shipping raw
        sched = len(B.get_backend("xla").capacity_schedule("chunked", 4, 2048))
        for okc, steps, wb in zip(st.chunk_ok, st.chunk_retry_steps,
                                  st.chunk_wire_bytes):
            if not okc:
                assert steps == sched - 1
                assert wb == pytest.approx(2.0 * 4096 / len(st.chunk_ok))

    def test_whole_tensor_route_also_retries(self):
        """The geometric schedule applies per tensor on the whole-tensor
        path too (it replaced the chunked-only 2x retry)."""
        n = 4096
        bits = np.full(n, np.uint16(120 << 7), dtype=np.uint16)
        bits[:40] = np.uint16(7 << 7)   # one heavy codec chunk
        cache = self._stream_cache(bits)
        tc = TransferConfig(codebook=BF16_CB, cap=4, chunk=1024, n_chunks=1)
        sess = TransferPlan.build(cache, tc).session()
        out = sess.transfer(cache)
        _assert_bit_identical(cache, out)
        st = sess.last_stats
        assert st.leaf_ok["a"] is True
        assert st.n_retry_steps >= 1
        assert st.leaf_wire_bytes["a"] < 2.0 * n

    def test_engine_records_retry_steps(self):
        from repro.configs.base import get_config
        from repro.models.kvcache import DecodeState
        from repro.serving.engine import DisaggregatedEngine
        n = 8192
        bits = np.full(n, np.uint16(120 << 7), dtype=np.uint16)
        bits[:40] = np.uint16(7 << 7)
        cache = self._stream_cache(np.asarray(bits))
        eng = DisaggregatedEngine(get_config("smollm-135m").reduced(), None,
                                  BF16_CB, compress=True, cap=4,
                                  chunk=1024, n_chunks=2)
        state = DecodeState(cache=cache, cache_len=jnp.zeros((1,), jnp.int32))
        out = eng.transfer(state)
        _assert_bit_identical(cache, out.cache)
        assert eng.stats.codec_ok
        assert eng.stats.chunk_retries == 1
        assert eng.stats.chunk_retry_steps == 3


class TestPlanAwarePipelineModel:
    def test_equal_chunks_match_closed_form(self):
        p = CodecProfile(g_enc=600e9, g_dec=2000e9, ratio=1.33, link_bw=50e9,
                         fixed_overhead_s=1e-4)
        total = 1 << 30
        for n in (1, 3, 8):
            assert pipeline_makespan([total / n] * n, p) == pytest.approx(
                pipelined_transfer_time(total, p, n))

    def test_short_tail_chunk_beats_equal_split_assumption(self):
        p = CodecProfile(g_enc=600e9, g_dec=2000e9, ratio=1.33, link_bw=50e9)
        # 7 full chunks + a tiny tail (what alignment actually produces)
        chunks = [128e6] * 7 + [8e6]
        assert pipeline_makespan(chunks, p) < pipelined_transfer_time(
            sum(chunks), p, 7)

    def test_plan_estimate_uses_actual_segments(self):
        cache = _mixed_cache(seed=7)
        tc = TransferConfig(codebook=_cache_cb(cache), n_chunks=4)
        plan = TransferPlan.build(cache, tc)
        p = CodecProfile(g_enc=600e9, g_dec=2000e9, ratio=1.33, link_bw=50e9)
        est = plan.estimate_time(p)
        stream, fp8, out = plan.byte_split()
        assert stream == pytest.approx(sum(plan.chunk_raw_bytes()))
        # incompressible bytes (raw passthrough) pay FULL link cost, only
        # routed bytes get the codec ratio
        assert est == pytest.approx(
            pipeline_makespan(plan.chunk_raw_bytes(), p)
            + fp8 / (p.ratio * p.link_bw) + out / p.link_bw)

    def test_plan_aware_report_tracks_measured_totals(self):
        """transfer_report(plan=) must stay a function of the MEASURED
        totals: K-call accumulation scales both sides (speedup invariant),
        and raw-fallback-inflated wire bytes raise t_splitzip."""
        cache = _mixed_cache(seed=7)
        tc = TransferConfig(codebook=_cache_cb(cache), n_chunks=4)
        plan = TransferPlan.build(cache, tc)
        p = CodecProfile(g_enc=600e9, g_dec=2000e9, ratio=1.33, link_bw=50e9)
        raw = plan.raw_bytes()
        one = T.transfer_report(raw, raw / 1.33, p, n_chunks=4, plan=plan)
        many = T.transfer_report(8 * raw, 8 * raw / 1.33, p, n_chunks=4,
                                 plan=plan)
        assert many.speedup == pytest.approx(one.speedup)
        assert many.t_splitzip == pytest.approx(8 * one.t_splitzip)
        # all-raw fallback (wire == raw) must cost more than compressed wire
        degraded = T.transfer_report(raw, raw, p, n_chunks=4, plan=plan)
        assert degraded.t_splitzip > one.t_splitzip
        # pipeline overlap: still cheaper than the additive accounting
        additive = T.transfer_report(raw, raw / 1.33, p, n_chunks=1)
        assert one.t_splitzip < additive.t_splitzip


MESH_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.core import codebook as cbm
from repro.launch.mesh import make_mesh
from repro.serving.plan import TransferConfig, TransferPlan

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
def kv(shape):
    x = rng.normal(size=shape) * rng.choice([0.25, 1.0, 4.0], size=shape)
    return jnp.asarray(x, dtype=jnp.bfloat16)
cache = {"k": kv((2, 4, 64, 2, 16)), "v": kv((2, 4, 64, 2, 16)),
         "ssm": jnp.asarray(rng.normal(size=(2, 4, 32)), jnp.float32),
         "act8": jnp.asarray(rng.normal(size=(2, 128)) * 0.5,
                             jnp.float8_e5m2)}
cb = cbm.calibrate([np.asarray(jax.lax.bitcast_convert_type(
    cache["k"], jnp.uint16))], k=16)

def run(n_chunks):
    tc = TransferConfig(codebook=cb, chunk=256, cap=16, n_chunks=n_chunks,
                        compress_fp32=True)
    sess = TransferPlan.build(cache, tc, mesh=mesh).session()
    return sess.transfer(cache)

whole, piped = run(1), run(4)
def bits(t):
    return [np.asarray(jax.lax.bitcast_convert_type(
        x, {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[x.dtype.itemsize]))
        for x in jax.tree.leaves(t)]
assert all(np.array_equal(a, b) for a, b in zip(bits(cache), bits(piped)))
assert all(np.array_equal(a, b) for a, b in zip(bits(whole), bits(piped)))
print("MESH-PARITY-OK")
"""


class TestCrossPodParity:
    def test_chunked_mesh_matches_whole_tensor_subprocess(self):
        """Acceptance: a TransferPlan executed on a 2-pod mesh with
        n_chunks > 1 (per-chunk ppermute, double-buffered) is bit-identical
        to the whole-tensor path AND to the input, fp32 + fp8 included.
        Own process: the host-device-count override must precede jax init."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
            "PYTHONPATH", "")
        out = subprocess.run([sys.executable, "-c", MESH_PARITY_SCRIPT],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "MESH-PARITY-OK" in out.stdout
