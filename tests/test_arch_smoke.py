"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-style step on CPU, asserting output shapes and no NaNs; plus a
prefill -> decode consistency check for every family with a decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config
from repro.models import model as M

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    full = get_config(request.param)
    red = full.reduced()
    params = M.init_params(red, jax.random.PRNGKey(0))
    return full, red, params


def test_full_config_matches_assignment(arch):
    full, _, _ = arch
    # spot-check the exact assigned dimensions
    expect = {
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    }[full.name]
    assert (full.num_layers, full.d_model, full.num_heads, full.num_kv_heads,
            full.d_ff, full.vocab_size) == expect


def test_forward_shapes_and_finite(arch):
    _, red, params = arch
    batch = M.make_inputs(red, SMOKE_SHAPE)
    logits, _, aux = M.forward(params, batch, red, kv_block=16)
    b, s = 2, 32
    assert logits.shape[0] == b and logits.shape[-1] == red.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


def test_train_step_loss_finite_and_grads_flow(arch):
    _, red, params = arch
    batch = M.make_inputs(red, SMOKE_SHAPE)

    def loss(p):
        return M.loss_fn(p, batch, red, kv_block=16, remat=True)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


def test_prefill_then_decode_consistency(arch):
    """Decode at position S must match full-forward logits at position S
    (teacher-forced): validates every cache layout end-to-end."""
    _, red, params = arch
    if red.encoder_only:
        pytest.skip("encoder-only: no decode path")
    s = 16
    batch = M.make_inputs(red, SMOKE_SHAPE, seq=s + 1)
    # prompt = everything except the final text token
    prompt = {k: (v[:, :-1] if k in ("tokens", "labels") else v)
              for k, v in batch.items()}
    total_prompt = prompt["tokens"].shape[1] + (
        red.frontend_len if red.frontend == "vision_patches" else 0)
    last_logits, state = M.prefill(params, prompt, red, max_seq=total_prompt + 8)
    next_tok = batch["tokens"][:, -1:]
    dec_logits, state2 = M.decode_step(params, next_tok, state, red)

    full_logits, _, _ = M.forward(params, batch, red, kv_block=16)
    ref = full_logits[:, -1]

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(ref, np.float32),
        rtol=0.08, atol=0.08)
    assert int(state2.cache_len[0]) == total_prompt + 1


def test_reduced_param_count_sane(arch):
    full, red, params = arch
    n_actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert n_actual > 1000
    # full-config analytic param count in a plausible band
    n_full = full.param_count()
    expected_band = {
        "minitron-4b": (3e9, 6.5e9),
        "smollm-135m": (0.9e8, 2.2e8),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "minicpm3-4b": (3e9, 5.5e9),
        "qwen3-moe-235b-a22b": (1.8e11, 2.9e11),
        "qwen3-moe-30b-a3b": (2.2e10, 3.8e10),
        "pixtral-12b": (1.0e10, 1.5e10),
        "recurrentgemma-9b": (7e9, 1.2e10),
        "hubert-xlarge": (8e8, 1.4e9),
        "mamba2-2.7b": (2.2e9, 3.4e9),
    }[full.name]
    assert expected_band[0] < n_full < expected_band[1], n_full
