"""Single-pass fused Pallas codec tests.

The fused kernels promise three things, pinned down here:

1. **Stream parity** — the fused encode's CompressedTensor is bit-identical
   to the pure-XLA reference (and hence to the two-stage path) on every
   input class: special values, fp8, all-escape, zero-escape, and the
   capacity-overflow boundary (``esc_count == cap`` and ``cap + 1``).
2. **Single-launch structure** — one ``pallas_call`` per direction and no
   XLA scatter tail in the fused decode (jaxpr-level assertions; the
   benchmark re-checks this on lowered HLO).
3. **Engine integration** — the chunked pipelined transfer engine with the
   fused backend reassembles caches bit-identically, and the adaptive
   capacity retry recovers heavy-tailed chunks before the raw fallback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import codebook as cbm
from repro.core import codec as C
from repro.kernels import ops, splitzip_decode, splitzip_encode, twostage
from repro.serving import transfer as T

CODEBOOK = tuple(range(118, 134))
BF16_CB = cbm.Codebook(fmt="bf16", exponents=CODEBOOK)
FP8_CB = cbm.Codebook(fmt="fp8_e5m2", exponents=tuple(range(8, 24)))

BF16_SPECIALS = np.array(
    [0x7FC0, 0x7FC1, 0xFFC0, 0x7F80, 0xFF80, 0x0000, 0x8000,
     0x0001, 0x8001, 0x7F7F, 0xFF7F, 0x0080, 0xFFFF, 0x7FFF],
    dtype=np.uint16)


def _bf16_specials_input(seed=0, n=8192):
    rng = np.random.default_rng(seed)
    bits = np.array(jax.lax.bitcast_convert_type(
        jnp.asarray(rng.standard_normal(n).astype(np.float32)
                    * np.exp(rng.standard_normal(n))).astype(jnp.bfloat16),
        jnp.uint16))
    pos = rng.choice(n, size=4 * BF16_SPECIALS.size, replace=False)
    bits[pos] = np.tile(BF16_SPECIALS, 4)
    return jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)


def _assert_streams_equal(ct_a, ct_b):
    for la, lb in zip(jax.tree.leaves(ct_a), jax.tree.leaves(ct_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _exact_escape_input(n_escapes: int, chunk: int = 1024):
    """One chunk with exactly ``n_escapes`` escaping elements (exponent 7 is
    not in CODEBOOK; exponent 120 is) at scattered positions."""
    bits = np.full(chunk, np.uint16(120 << 7), dtype=np.uint16)
    pos = np.linspace(0, chunk - 1, n_escapes).astype(int) if n_escapes else []
    for p in pos:
        bits[p] = np.uint16(7 << 7) | np.uint16(p % 128)  # varied mantissae
    return jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)


class TestFusedStreamParity:
    def test_bf16_specials_streams_and_roundtrip(self):
        x = _bf16_specials_input(seed=1)
        ct_f = ops.encode(x, BF16_CB)
        _assert_streams_equal(ct_f, C.encode(x, BF16_CB))
        y = ops.decode(ct_f)
        np.testing.assert_array_equal(
            np.asarray(C.to_bits(x, "bf16")),
            np.asarray(C.to_bits(y, "bf16")))

    def test_fp8_streams_and_roundtrip(self):
        rng = np.random.default_rng(2)
        # biased toward covered exponents so capacity holds, plus specials
        e = rng.choice(np.arange(8, 24), size=4096).astype(np.uint8)
        bits = ((e << 2) | rng.integers(0, 4, 4096)).astype(np.uint8)
        bits[:64] = rng.integers(0, 256, 64)  # escapes incl. NaN/Inf patterns
        bits = jnp.asarray(bits)
        ct_f = ops.encode(bits, FP8_CB)
        _assert_streams_equal(ct_f, C.encode(bits, FP8_CB))
        np.testing.assert_array_equal(
            np.asarray(bits), np.asarray(C.to_bits(ops.decode(ct_f),
                                                   "fp8_e5m2")))

    def test_all_escape_tensor(self):
        bits = jnp.full((4096,), np.uint16(7 << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        ct_f = ops.encode(x, BF16_CB)
        ct_r = C.encode(x, BF16_CB)
        _assert_streams_equal(ct_f, ct_r)
        assert not bool(ct_f.ok)
        assert np.asarray(ct_f.esc_count).tolist() == [1024] * 4

    def test_zero_escape_tensor(self):
        bits = jnp.full((4096,), np.uint16(120 << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        ct_f = ops.encode(x, BF16_CB)
        _assert_streams_equal(ct_f, C.encode(x, BF16_CB))
        assert bool(ct_f.ok)
        assert int(jnp.sum(ct_f.esc_count)) == 0
        np.testing.assert_array_equal(
            np.asarray(bits), np.asarray(C.to_bits(ops.decode(ct_f), "bf16")))

    @pytest.mark.parametrize("n_esc,expect_ok", [(63, True), (64, True),
                                                 (65, False)])
    def test_capacity_boundary(self, n_esc, expect_ok):
        """esc_count == cap is still ok; cap + 1 overflows — and the streams
        (first cap entries, TRUE count) match the reference either way."""
        x = _exact_escape_input(n_esc)
        ct_f = ops.encode(x, BF16_CB, cap=64)
        ct_r = C.encode(x, BF16_CB, cap=64)
        _assert_streams_equal(ct_f, ct_r)
        assert bool(ct_f.ok) is expect_ok
        assert int(ct_f.esc_count[0]) == n_esc
        if expect_ok:
            np.testing.assert_array_equal(
                np.asarray(C.to_bits(x, "bf16")),
                np.asarray(C.to_bits(ops.decode(ct_f), "bf16")))

    def test_fused_equals_two_stage(self):
        """Same layout, bit-identical streams and decode across the A/B pair."""
        x = _bf16_specials_input(seed=3, n=16384)
        ct_f = ops.encode(x, BF16_CB)
        ct_t = twostage.encode(x, BF16_CB)
        _assert_streams_equal(ct_f, ct_t)
        np.testing.assert_array_equal(
            np.asarray(C.to_bits(ops.decode(ct_f), "bf16")),
            np.asarray(C.to_bits(twostage.decode(ct_t), "bf16")))

    def test_backend_fused_flag(self):
        be_f = B.PallasBackend()
        be_t = B.PallasBackend(fused=False)
        assert be_f.fused and not be_t.fused
        x = _bf16_specials_input(seed=4, n=8192)
        _assert_streams_equal(be_f.encode(x, BF16_CB), be_t.encode(x, BF16_CB))

    def test_global_layout_streams_match_reference(self):
        x = _bf16_specials_input(seed=5, n=16384)
        ct_f = ops.encode(x, BF16_CB, layout="global", cap=4096)
        ct_r = C.encode(x, BF16_CB, layout="global", cap=4096)
        _assert_streams_equal(ct_f, ct_r)
        # decode uses the sparse bit-patch (bounded, no full-stream pass)
        np.testing.assert_array_equal(
            np.asarray(C.to_bits(x, "bf16")),
            np.asarray(C.to_bits(ops.decode(ct_f), "bf16")))

    def test_global_layout_chunk_overflow_is_conservative(self):
        """A chunk overflowing the level-1 buffer forces ok=False (raw
        fallback) even when the global capacity would fit — losslessness is
        preserved by being conservative, never by dropping escapes."""
        bits = np.full(4096, np.uint16(120 << 7), dtype=np.uint16)
        bits[: splitzip_encode.MAX_FUSED_CAP + 1] = np.uint16(7 << 7)  # 1 chunk
        x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
        ct_f = ops.encode(x, BF16_CB, layout="global", cap=4096)
        assert not bool(ct_f.ok)
        assert bool(C.encode(x, BF16_CB, layout="global", cap=4096).ok)

    def test_oversized_cap_delegates_to_two_stage(self):
        x = _bf16_specials_input(seed=6, n=8192)
        cap = splitzip_encode.MAX_FUSED_CAP * 8
        ct = ops.encode(x, BF16_CB, cap=cap)
        _assert_streams_equal(ct, C.encode(x, BF16_CB, cap=cap))
        np.testing.assert_array_equal(
            np.asarray(C.to_bits(x, "bf16")),
            np.asarray(C.to_bits(ops.decode(ct), "bf16")))

    def test_decode_bits_equals_decode(self):
        x = _bf16_specials_input(seed=7, n=8192)
        for be in (B.get_backend("xla"), B.PallasBackend(),
                   B.PallasBackend(fused=False)):
            ct = be.encode(x, BF16_CB)
            np.testing.assert_array_equal(
                np.asarray(be.decode_bits(ct)),
                np.asarray(C.to_bits(be.decode(ct), "bf16").reshape(-1)))


class TestSingleLaunchStructure:
    """The launch-count claim, asserted at the jaxpr level (the benchmark
    re-asserts on lowered HLO): fused encode/decode each contain exactly one
    pallas_call, and fused decode has NO scatter tail."""

    @staticmethod
    def _prims(fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args)
        names = []

        def walk(j):
            for eqn in j.eqns:
                names.append(eqn.primitive.name)
                for sub in eqn.params.values():
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)
        walk(jaxpr.jaxpr)
        return names

    def test_fused_encode_single_pallas_call_no_scatter(self):
        x = _bf16_specials_input(seed=8, n=8192)
        prims = self._prims(lambda v: ops.encode(v, BF16_CB), x)
        assert prims.count("pallas_call") == 1
        assert not any(p.startswith("scatter") for p in prims)

    def test_fused_decode_single_pallas_call_no_scatter(self):
        x = _bf16_specials_input(seed=8, n=8192)
        ct = ops.encode(x, BF16_CB)
        prims = self._prims(ops.decode, ct)
        assert prims.count("pallas_call") == 1
        assert not any(p.startswith("scatter") for p in prims)

    def test_two_stage_decode_has_scatter_tail(self):
        """The structural regression the fusion removes, pinned as contrast."""
        x = _bf16_specials_input(seed=8, n=8192)
        ct = twostage.encode(x, BF16_CB)
        prims = self._prims(twostage.decode, ct)
        assert any(p.startswith("scatter") for p in prims)

    def test_fused_kernels_lower_for_tpu_without_execution(self):
        """The fused kernels must lower (interpret=False) even though we
        can't run them on CPU — the TPU-targeting proof for the fused path."""
        bits = jax.ShapeDtypeStruct((64, 1024), jnp.uint16)
        try:
            low_e = jax.jit(lambda b: splitzip_encode.encode_fused(
                b, CODEBOOK, cap=64, interpret=False)).lower(bits)
            a = jax.ShapeDtypeStruct((64, 1024), jnp.uint8)
            p = jax.ShapeDtypeStruct((64, 512), jnp.uint8)
            ep = jax.ShapeDtypeStruct((64, 64), jnp.uint16)
            ev = jax.ShapeDtypeStruct((64, 64), jnp.uint8)
            ec = jax.ShapeDtypeStruct((64, 1), jnp.int32)
            low_d = jax.jit(lambda *t: splitzip_decode.decode_fused(
                *t, CODEBOOK, interpret=False)).lower(p, a, ep, ev, ec)
        except Exception:
            pytest.skip("pallas TPU lowering unavailable on this backend")
        for low in (low_e, low_d):
            txt = low.as_text()
            assert "custom_call" in txt or "tpu" in txt.lower()


class TestAutoBackend:
    def test_auto_registered_and_resolves(self):
        assert "auto" in B.available_backends()
        be = B.get_backend("auto")
        expect = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert be.name == expect

    def test_auto_roundtrip_through_transfer_config(self):
        x = _bf16_specials_input(seed=9, n=4096)
        tc = T.TransferConfig(codebook=BF16_CB, backend="auto")
        be = tc.get_backend()
        np.testing.assert_array_equal(
            np.asarray(C.to_bits(x, "bf16")),
            np.asarray(C.to_bits(jnp.asarray(be.decode(be.encode(x, BF16_CB))
                                             ).reshape(x.shape), "bf16")))


def _toy_cache(seed=0):
    rng = np.random.default_rng(seed)

    def kv(shape):
        x = rng.normal(size=shape) * rng.choice([0.25, 1.0, 4.0], size=shape)
        return jnp.asarray(x, dtype=jnp.bfloat16)

    return {"k": kv((4, 2, 128, 4, 32)), "v": kv((4, 2, 128, 4, 32)),
            "ssm": jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)}


def _assert_bit_identical(a_tree, b_tree):
    for a, b in zip(jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)):
        w = {2: jnp.uint16, 4: jnp.uint32}[a.dtype.itemsize]
        np.testing.assert_array_equal(
            np.asarray(jax.lax.bitcast_convert_type(a, w)),
            np.asarray(jax.lax.bitcast_convert_type(b, w)))


class TestChunkedEngineWithFusedBackend:
    @pytest.mark.parametrize("n_chunks", (1, 4))
    def test_chunked_parity_fused_vs_xla(self, n_chunks):
        cache = _toy_cache(seed=10)
        leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
                  for x in jax.tree.leaves(cache) if x.dtype == jnp.bfloat16]
        cb = cbm.calibrate(leaves, k=16)
        out_p, st_p = T.transfer_cache_chunked(
            cache, T.TransferConfig(codebook=cb, backend="pallas",
                                    n_chunks=n_chunks))
        out_x, st_x = T.transfer_cache_chunked(
            cache, T.TransferConfig(codebook=cb, backend="xla",
                                    n_chunks=n_chunks))
        _assert_bit_identical(cache, out_p)
        _assert_bit_identical(out_x, out_p)
        assert st_p.chunk_wire_bytes == st_x.chunk_wire_bytes
        assert st_p.all_ok and st_p.n_retries == 0

    def test_adaptive_capacity_recovers_heavy_tailed_chunk(self):
        """A chunk that overflows cap but fits 2·cap is retried (not rawed):
        ok stays True, the retry is recorded, and the wire bytes stay
        compressed."""
        rng = np.random.default_rng(11)
        n = 8 * 1024
        bits = np.full(n, np.uint16(120 << 7), dtype=np.uint16)
        # ~48 escapes per 1024-chunk: over cap=32, under 2*cap=64
        esc = rng.choice(n, size=(48 * n) // 1024, replace=False)
        bits[esc] = np.uint16(7 << 7)
        cache = {"a": jax.lax.bitcast_convert_type(jnp.asarray(bits),
                                                   jnp.bfloat16)}
        tc = T.TransferConfig(codebook=BF16_CB, cap=32, n_chunks=4,
                              backend="pallas")
        out, stats = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert stats.all_ok
        assert stats.n_retries >= 1
        raw = 2.0 * n / len(stats.chunk_wire_bytes)
        for wb in stats.chunk_wire_bytes:
            assert wb < raw

    def test_adaptive_retry_global_layout_clears_level1_overflow(self):
        """fused-global's conservative ok (level-1 chunk buffer overflow)
        must not make the doubled-cap retry futile: for_retry hands the
        re-encode to the two-stage structure, which has no level-1 bound,
        so a chunk whose escapes fit 2x the global budget is recovered."""
        n = 16 * 1024
        bits = np.full(n, np.uint16(120 << 7), dtype=np.uint16)
        # 200 escapes concentrated in ONE codec chunk: over the fused
        # kernel's level-1 cap (128) and over the 1% global budget (128 for
        # an 8192-element segment), but under the doubled budget (256)
        bits[:200] = np.uint16(7 << 7)
        cache = {"a": jax.lax.bitcast_convert_type(jnp.asarray(bits),
                                                   jnp.bfloat16)}
        tc = T.TransferConfig(codebook=BF16_CB, layout="global",
                              backend="pallas", n_chunks=2)
        out, stats = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert stats.all_ok
        assert stats.n_retries == 1
        raw_seg = 2.0 * n / len(stats.chunk_wire_bytes)
        assert all(wb < raw_seg for wb in stats.chunk_wire_bytes)

    def test_adaptive_capacity_still_falls_back_to_raw(self):
        """Doubling can't save an all-escape chunk: retry is recorded, the
        chunk ships raw, and the cache is still bit-exact."""
        bad = np.random.default_rng(12).integers(0, 1 << 16, 4096
                                                 ).astype(np.uint16)
        cache = {"a": jax.lax.bitcast_convert_type(jnp.asarray(bad),
                                                   jnp.bfloat16)}
        tc = T.TransferConfig(codebook=BF16_CB, cap=4, n_chunks=2,
                              backend="pallas")
        out, stats = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert not stats.all_ok
        assert stats.n_retries == len([ok for ok in stats.chunk_ok if not ok])
        for okc, wb in zip(stats.chunk_ok, stats.chunk_wire_bytes):
            if not okc:
                assert wb == pytest.approx(2.0 * 4096 / len(stats.chunk_ok))
