"""ISSUE 5 invariants: pluggable link/admission policies + calibrated profiles.

Link-policy suite (on the event-driven scheduler, `repro.serving.policy`):
FIFO-vs-SJF ordering and tail trade, EDF feasibility (never violates a
deadline set FIFO meets — Jackson's rule), speculative admission (overlap
without breaking link-occupancy conservation or starving ready requests),
registry behaviour, and cross-policy event determinism.

Calibrated-profile suite (`repro.core.profile`): measure -> serialize ->
load -> bit-identical ``estimate_time``, source resolution ('paper' /
explicit path / unknown), schema versioning, and per-bucket overflow priors
flowing engine -> scheduler."""

import math
import random

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import codebook as cbm
from repro.core import profile as prof_mod
from repro.core.pipeline import CodecProfile
from repro.core.profile import (PAPER_G_ENC, CalibratedProfile, load_profiles,
                                paper_profile, resolve_profile, save_profiles)
from repro.serving import policy as pol
from repro.serving.engine import DisaggregatedEngine
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)

KV_BYTES_TOK = 2 * 32 * 8 * 128 * 2
PROF = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=25e9)
STEP = 1e-6   # decode step far below transfer durations: TTFT ~ link order


def _cfg(**kw):
    base = dict(kv_bytes_per_token=KV_BYTES_TOK, profile=PROF, compress=True,
                prefill_time_per_token=0.0, decode_time_per_step=STEP,
                max_prefill_batch=64, max_decode_slots=64)
    base.update(kw)
    return SchedulerConfig(**base)


def _run(cfg, reqs):
    s = DisaggregatedScheduler(cfg)
    for r in reqs:
        s.submit(r)
    return s, s.run()


def _transfer_dur(prompt_len, **kw):
    """The charged single-occupancy duration for one request (probe run)."""
    _, done = _run(_cfg(**kw), [Request(rid=0, arrival=0.0,
                                        prompt_len=prompt_len,
                                        max_new_tokens=1)])
    return done[0].transfer_done - done[0].link_start


class TestLinkOrdering:
    def test_sjf_orders_link_by_transfer_duration(self):
        """SJF dispatches the idle link to the queued request with the
        smallest plan-estimated duration; FIFO to the earliest prefill."""
        prompts = [16384, 2048, 8192, 4096]
        reqs = lambda: [Request(rid=i, arrival=0.0, prompt_len=p,
                                max_new_tokens=1)
                        for i, p in enumerate(prompts)]
        _, done = _run(_cfg(policy="sjf"), reqs())
        order = [r.prompt_len for r in sorted(done, key=lambda r: r.link_start)]
        assert order == sorted(prompts)
        _, done = _run(_cfg(policy="fifo"), reqs())
        order = [r.prompt_len for r in sorted(done, key=lambda r: r.link_start)]
        assert order == prompts              # rid ties on equal prefill_done

    def test_sjf_improves_mean_ttft_but_longest_pays_tail(self):
        """The classic SJF trade on a contended link: shorts overtake the
        queued long transfer, so mean TTFT drops but the long request — and
        with staggered short arrivals, the p99 tail — degrades vs FIFO."""
        d_short = _transfer_dur(1024)
        # rid 0 occupies the link first under BOTH policies (only request
        # queued at t=0); the long rid 1 then queues behind it, and shorts
        # keep arriving fast enough that SJF always finds one to overtake
        # the long with (non-preemptive: only QUEUED requests are overtaken)
        def trace():
            reqs = [Request(rid=0, arrival=0.0, prompt_len=1024,
                            max_new_tokens=1),
                    Request(rid=1, arrival=0.1 * d_short, prompt_len=16384,
                            max_new_tokens=1)]
            reqs += [Request(rid=2 + k, arrival=(0.2 + 0.9 * k) * d_short,
                             prompt_len=1024, max_new_tokens=1)
                     for k in range(8)]
            return reqs

        fifo = {r.rid: r for r in _run(_cfg(policy="fifo"), trace())[1]}
        sjf = {r.rid: r for r in _run(_cfg(policy="sjf"), trace())[1]}
        ttft = lambda by: {rid: r.first_token_time - r.arrival
                           for rid, r in by.items()}
        t_f, t_s = ttft(fifo), ttft(sjf)
        n = len(t_f)
        assert sum(t_s.values()) / n < sum(t_f.values()) / n   # mean: SJF wins
        assert t_s[1] > t_f[1]                                 # the long pays
        assert max(t_s.values()) > max(t_f.values())           # tail: SJF loses
        # non-preemption: the in-flight pilot transfer was never disturbed
        assert sjf[0].link_start == fifo[0].link_start
        assert sjf[0].transfer_done == fifo[0].transfer_done

    def test_duplicate_field_identical_requests_both_served(self):
        """Request is an eq-by-value dataclass: two field-identical requests
        in the same prefill batch must still get one link occupancy EACH
        (dispatch removes the policy's pick by identity, not list.remove)."""
        reqs = [Request(rid=7, arrival=0.0, prompt_len=4096, max_new_tokens=1),
                Request(rid=7, arrival=0.0, prompt_len=4096, max_new_tokens=1)]
        s, done = _run(_cfg(policy="sjf"), reqs)
        assert len(done) == 2
        ivs = sorted((r.link_start, r.transfer_done) for r in done)
        assert ivs[0][1] <= ivs[1][0] + 1e-12   # two distinct occupancies
        assert s.link_busy_s == pytest.approx(sum(b - a for a, b in ivs))

    def test_edf_meets_any_feasible_deadline_set_fifo_meets(self):
        """Jackson's rule: for simultaneously released requests EDF minimizes
        maximum lateness, so ANY deadline assignment FIFO satisfies, EDF
        satisfies too — pinned over randomized traces and random slack."""
        rng = random.Random(5)
        for trial in range(4):
            prompts = [rng.choice([1024, 2048, 4096, 8192, 16384])
                       for _ in range(10)]
            reqs = lambda dl: [
                Request(rid=i, arrival=0.0, prompt_len=p, max_new_tokens=1,
                        deadline=dl[i] if dl else math.inf)
                for i, p in enumerate(prompts)]
            _, done = _run(_cfg(policy="fifo"), reqs(None))
            # feasible by construction: FIFO meets each with >= 5-step slack
            # (the slack dominates any step-boundary jitter EDF can add)
            deadlines = {r.rid: r.first_token_time
                         + rng.uniform(5 * STEP, 500 * STEP) for r in done}
            _, done = _run(_cfg(policy="edf"), reqs(deadlines))
            for r in done:
                assert r.first_token_time <= deadlines[r.rid] + 1e-12, \
                    f"trial {trial}: EDF missed a FIFO-feasible deadline"

    def test_edf_meets_tight_deadline_fifo_misses(self):
        """The property above is not vacuous: a tight deadline on a short
        request queued behind a long one is missed by FIFO, met by EDF."""
        d_short = _transfer_dur(1024)
        deadline = 3 * d_short               # < long transfer, > short's own
        reqs = lambda: [Request(rid=0, arrival=0.0, prompt_len=16384,
                                max_new_tokens=1),
                        Request(rid=1, arrival=0.0, prompt_len=1024,
                                max_new_tokens=1, deadline=deadline)]
        _, done = _run(_cfg(policy="fifo"), reqs())
        assert {r.rid: r for r in done}[1].first_token_time > deadline
        _, done = _run(_cfg(policy="edf"), reqs())
        assert {r.rid: r for r in done}[1].first_token_time <= deadline

    def test_edf_without_deadlines_degenerates_to_fifo(self):
        """No per-request deadline and no cfg.slo_s: every key is
        (+inf, prefill_done, rid) — EDF must reproduce FIFO exactly."""
        reqs = lambda: [Request(rid=i, arrival=i * 1e-4,
                                prompt_len=1024 * (1 + i % 4),
                                max_new_tokens=2) for i in range(8)]
        snap = lambda policy: {
            r.rid: (r.link_start, r.transfer_done, r.first_token_time,
                    r.finish_time)
            for r in _run(_cfg(policy=policy), reqs())[1]}
        assert snap("edf") == snap("fifo")

    def test_edf_slo_fallback_orders_by_arrival_plus_slo(self):
        """A request with no explicit deadline inherits arrival + cfg.slo_s:
        a later-arriving request then outranks an earlier one whose explicit
        deadline is looser."""
        d_short = _transfer_dur(1024)
        pilot = Request(rid=0, arrival=0.0, prompt_len=1024, max_new_tokens=1)
        loose = Request(rid=1, arrival=0.1 * d_short, prompt_len=1024,
                        max_new_tokens=1, deadline=1e6)
        tight = Request(rid=2, arrival=0.2 * d_short, prompt_len=1024,
                        max_new_tokens=1)   # no deadline -> arrival + slo_s
        _, done = _run(_cfg(policy="edf", slo_s=d_short), [pilot, loose, tight])
        by = {r.rid: r for r in done}
        assert by[2].link_start < by[1].link_start


class TestSpeculativeAdmission:
    def test_spec_overlaps_slot_setup_with_transfer(self):
        """admit_latency_s (slot setup) is the wait 'spec' hides under the
        transfer: with setup >> one decode step, FIFO pays it after
        transfer_done, spec has it done by then.  Tokens still never precede
        the transfer."""
        lat = 100 * STEP
        reqs = lambda: [Request(rid=0, arrival=0.0, prompt_len=16384,
                                max_new_tokens=2)]
        _, done_f = _run(_cfg(policy="fifo", admit_latency_s=lat), reqs())
        _, done_s = _run(_cfg(policy="spec", admit_latency_s=lat), reqs())
        f, s = done_f[0], done_s[0]
        assert lat < s.transfer_done - s.link_start   # setup fits under xfer
        assert s.first_token_time >= s.transfer_done  # never precedes data
        assert s.first_token_time < f.first_token_time - 0.5 * lat
        assert s.admit_time == s.link_start           # claimed at link grant
        assert f.admit_time == f.transfer_done

    def test_spec_preserves_link_occupancy_conservation(self):
        """Speculative admission touches only the decode-slot grant; the link
        schedule must stay bit-identical to FIFO — exactly one occupancy per
        request, non-overlapping, conservation of total busy time."""
        reqs = lambda: [Request(rid=i, arrival=0.0, prompt_len=8192,
                                max_new_tokens=4) for i in range(6)]
        cfg = dict(max_decode_slots=1, decode_time_per_step=1e-3,
                   admit_latency_s=5e-4)
        s_fifo, done_f = _run(_cfg(policy="fifo", **cfg), reqs())
        s_spec, done_s = _run(_cfg(policy="spec", **cfg), reqs())
        link = lambda done: sorted((r.link_start, r.transfer_done)
                                   for r in done)
        ivs = link(done_s)
        assert ivs == link(done_f)                     # same link schedule
        durs = [b - a for a, b in ivs]
        for (_, b0), (a1, _) in zip(ivs, ivs[1:]):
            assert a1 >= b0 - 1e-12                    # never overlapping
        assert s_spec.link_busy_s == pytest.approx(sum(durs))
        assert s_spec.link_busy_s == pytest.approx(s_fifo.link_busy_s)
        assert max(durs) == pytest.approx(min(durs))   # equal prompts

    def test_spec_never_starves_ready_request(self):
        """A completed transfer waiting for admission always outranks the
        in-flight transfer's speculative claim on a freed slot."""
        d = _transfer_dur(8192)
        # one slot; A decodes for 1.5*d, so the slot frees while B (transfer
        # done at 2d) waits in the admission queue and C still holds the link
        # (its transfer ends at 3d): B must get the slot, not C.
        step = d / 4
        reqs = [Request(rid=0, arrival=0.0, prompt_len=8192, max_new_tokens=6),
                Request(rid=1, arrival=0.0, prompt_len=8192, max_new_tokens=1),
                Request(rid=2, arrival=0.0, prompt_len=8192, max_new_tokens=1)]
        _, done = _run(_cfg(policy="spec", max_decode_slots=1,
                            decode_time_per_step=step), reqs)
        by = {r.rid: r for r in done}
        assert by[1].admit_time < by[2].admit_time
        assert by[1].admit_time < by[2].transfer_done  # granted while C flies
        assert by[2].admit_time >= by[1].finish_time


class TestPolicyRegistry:
    def test_builtin_policies_registered(self):
        assert {"fifo", "sjf", "edf", "spec"} <= set(pol.available_policies())

    def test_unknown_policy_raises_with_available_list(self):
        with pytest.raises(KeyError, match="fifo"):
            DisaggregatedScheduler(_cfg(policy="nope"))

    def test_custom_policy_plugs_into_dispatch(self):
        """An out-of-tree registration is picked up by name — the scheduler
        resolves purely through the registry."""
        class LongestFirst(pol.LinkPolicy):
            name = "test-longest-first"

            def link_key(self, req, est_transfer_s, cfg):
                return (-est_transfer_s, req.prefill_done, req.rid)

        pol.register_policy("test-longest-first", LongestFirst)
        prompts = [2048, 16384, 4096, 8192]
        _, done = _run(_cfg(policy="test-longest-first"),
                       [Request(rid=i, arrival=0.0, prompt_len=p,
                                max_new_tokens=1)
                        for i, p in enumerate(prompts)])
        order = [r.prompt_len for r in sorted(done, key=lambda r: r.link_start)]
        assert order == sorted(prompts, reverse=True)

    @pytest.mark.parametrize("policy", ["fifo", "sjf", "edf", "spec"])
    def test_event_determinism_under_interleaved_submission(self, policy):
        """Every registered policy keeps the event engine deterministic:
        identical request sets submitted in any order produce identical
        per-request timings (policy keys end with rid)."""
        rng = random.Random(11)

        def make():
            arrivals = [0.0, 0.0, 1e-3, 1e-3, 2e-3, 2e-3, 5e-3, 8e-3]
            return [Request(rid=i, arrival=a, prompt_len=2048 * (1 + i % 3),
                            max_new_tokens=1 + i % 3,
                            deadline=(0.5 + (i * 7 % 5)) if i % 2 else math.inf)
                    for i, a in enumerate(arrivals)]

        def snap(order):
            cfg = _cfg(policy=policy, max_prefill_batch=3, max_decode_slots=2,
                       decode_time_per_step=1e-3, slo_s=0.25,
                       admit_latency_s=1e-4)
            _, done = _run(cfg, order)
            return {r.rid: (r.prefill_done, r.link_start, r.transfer_done,
                            r.admit_time, r.first_token_time, r.finish_time)
                    for r in done}

        base = snap(make())
        for _ in range(3):
            order = make()
            rng.shuffle(order)
            assert snap(order) == base


class TestCalibratedProfiles:
    def _measure(self):
        return CalibratedProfile.measure(backend="xla", shapes=((512,),),
                                         repeats=1, warmup=0)

    def test_measure_serialize_load_identical_estimate_time(self, tmp_path):
        """The acceptance round trip: measure -> save_profiles ->
        load_profiles -> the SAME CalibratedProfile, and a TransferPlan
        charged from either gives bit-identical estimate_time."""
        cal = self._measure()
        assert cal.g_enc > 0 and cal.g_dec > 0
        assert cal.ratio > 1.0               # top-16-shaped synthetic workload
        assert cal.key == "xla/bf16" and cal.source == "measured"
        path = str(tmp_path / "profiles.json")
        assert save_profiles([cal], path) == path
        loaded = load_profiles(path)["xla/bf16"]
        assert loaded == cal                 # JSON floats round-trip exactly
        plan = TransferPlan.build(
            {"kv": jax.ShapeDtypeStruct((4096,), jnp.bfloat16)},
            TransferConfig(codebook=cbm.Codebook(
                fmt="bf16", exponents=tuple(range(112, 128)))))
        p0, p1 = cal.profile(25e9), loaded.profile(25e9)
        assert p0 == p1
        assert plan.estimate_time(p0) == plan.estimate_time(p1)
        # the materialized CodecProfile carries auditable provenance
        assert p0.source == "measured:xla/bf16"

    def test_resolve_profile_paper_source(self):
        p = resolve_profile("paper", link_bw=25e9)
        assert p.g_enc == PAPER_G_ENC and p.link_bw == 25e9
        assert p.source == "paper-h200"
        assert paper_profile(25e9) == p

    def test_resolve_profile_explicit_path(self, tmp_path):
        cal = self._measure()
        path = str(tmp_path / "profiles.json")
        save_profiles([cal], path)
        p = resolve_profile(path, link_bw=12.5e9, backend="xla")
        assert p == cal.profile(12.5e9)
        # an explicit path is a claim a calibration exists: missing -> raise
        with pytest.raises(FileNotFoundError):
            resolve_profile(str(tmp_path / "absent.json"), link_bw=1e9)
        # and an uncalibrated backend in an existing file -> KeyError
        with pytest.raises(KeyError, match="pallas/bf16"):
            resolve_profile(path, link_bw=1e9, backend="pallas")

    def test_resolve_calibration_measures_on_demand_and_persists(self, tmp_path):
        """The load-or-measure path behind '--profile measured' and fig2:
        first call measures and writes the file, the second loads the SAME
        calibration; a stale schema is an error, never silently replaced."""
        path = str(tmp_path / "profiles.json")
        cal = prof_mod.resolve_calibration(path, backend="xla",
                                           source="test-on-demand")
        assert cal.source == "test-on-demand"
        assert prof_mod.resolve_calibration(path, backend="xla") == cal
        (tmp_path / "profiles.json").write_text(
            '{"version": 0, "profiles": {}}\n')
        with pytest.raises(ValueError, match="schema version"):
            prof_mod.resolve_calibration(path)

    def test_resolve_profile_unknown_source(self):
        with pytest.raises(ValueError, match="unknown profile source"):
            resolve_profile("datasheet", link_bw=1e9)

    def test_load_profiles_schema_version_mismatch(self, tmp_path):
        path = tmp_path / "profiles.json"
        path.write_text('{"version": 0, "profiles": {}}\n')
        with pytest.raises(ValueError, match="schema version"):
            load_profiles(str(path))

    def test_scheduler_runs_from_calibrated_profile(self):
        """A measured profile drops into SchedulerConfig like any other and
        the what-if numbers inherit its provenance."""
        cal = self._measure()
        cfg = _cfg(profile=cal.profile(25e9))
        assert cfg.profile.source.startswith("measured")
        _, done = _run(cfg, [Request(rid=i, arrival=0.0, prompt_len=4096,
                                     max_new_tokens=2) for i in range(3)])
        out = summarize(done)
        assert out["n"] == 3 and out["mean_ttft_s"] > 0


class TestOverflowPriors:
    def test_per_bucket_prior_overrides_scalar(self):
        """A bucket covered by overflow_priors is charged its calibrated
        expected-retry inflation; uncovered buckets fall back to the scalar
        overflow_p (0 here -> no inflation)."""
        base = dict(bucket_tokens=1024, overflow_p=0.0)
        req = lambda p: [Request(rid=0, arrival=0.0, prompt_len=p,
                                 max_new_tokens=1)]
        plain = _run(_cfg(**base), req(1024))[1][0]
        primed = _run(_cfg(overflow_priors={1024: 0.9}, **base), req(1024))[1][0]
        assert (primed.transfer_done - primed.link_start
                > plain.transfer_done - plain.link_start)
        # a prompt in bucket 2048 is NOT covered by the prior: identical charge
        plain2 = _run(_cfg(**base), req(2048))[1][0]
        primed2 = _run(_cfg(overflow_priors={1024: 0.9}, **base), req(2048))[1][0]
        assert (primed2.transfer_done - primed2.link_start
                == pytest.approx(plain2.transfer_done - plain2.link_start))

    def test_engine_priors_bucket_observed_retries(self):
        """DisaggregatedEngine.overflow_priors aggregates per-length retry
        observations at the scheduler's bucket granularity and
        scheduler_config feeds them through."""
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
        eng = DisaggregatedEngine(get_config("smollm-135m").reduced(), None,
                                  cb, compress=True, profile=PROF)
        eng.stats.overflow_obs.update({1000: (10, 1), 1024: (10, 3),
                                       2000: (5, 5)})
        priors = eng.overflow_priors(1024)
        assert priors == {1024: pytest.approx(4 / 20), 2048: 1.0}
        sc = eng.scheduler_config(kv_bytes_per_token=KV_BYTES_TOK)
        assert sc.overflow_priors == priors
